"""Observability tests: histogram accuracy/merge laws, the Link stale-bucket
regression, cluster stats/telemetry aggregation, trace schema + nesting, and
the metrics export."""

import json
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the seeded-random shim
    from _hypothesis_shim import given, settings, st

from repro import obs
from repro.cluster.rebalance import rebalance
from repro.cluster.router import ClusterFrontEnd, NVMCluster
from repro.cluster.sharded import ShardedHashTable
from repro.core import FEConfig, FrontEnd, NVMBackend
from repro.core.sim import CostModel, Link
from repro.core.structures import RemoteHashTable
from repro.obs import GROWTH, LatencyHistogram, report

# ---------------------------------------------------------------- histograms

values = st.lists(st.integers(min_value=1, max_value=1 << 40),
                  min_size=1, max_size=300)


def _exact_rank(sorted_vals, p):
    rank = max(1, min(len(sorted_vals), math.ceil(p / 100.0 * len(sorted_vals))))
    return sorted_vals[rank - 1]


@settings(max_examples=80, deadline=None)
@given(values)
def test_histogram_percentiles_within_one_bucket(vals):
    h = LatencyHistogram()
    for v in vals:
        h.record(v)
    s = sorted(vals)
    for p in (50.0, 99.0, 99.9):
        exact = _exact_rank(s, p)
        est = h.percentile(p)
        assert exact / GROWTH * (1 - 1e-9) <= est <= exact * GROWTH * (1 + 1e-9), (
            f"p{p}: est {est} vs exact {exact} on {len(vals)} values"
        )
    assert h.count == len(vals)
    assert h.vmin == s[0] and h.vmax == s[-1]


@settings(max_examples=60, deadline=None)
@given(values, values)
def test_histogram_merge_commutes_and_matches_bulk(a_vals, b_vals):
    a = LatencyHistogram()
    b = LatencyHistogram()
    bulk = LatencyHistogram()
    for v in a_vals:
        a.record(v)
        bulk.record(v)
    for v in b_vals:
        b.record(v)
        bulk.record(v)
    ab = a.copy().merge(b)
    ba = b.copy().merge(a)
    assert ab == ba == bulk
    assert ab.percentiles((50, 99, 99.9)) == bulk.percentiles((50, 99, 99.9))


@settings(max_examples=40, deadline=None)
@given(values, values, values)
def test_histogram_merge_associative(a_vals, b_vals, c_vals):
    hs = []
    for vals in (a_vals, b_vals, c_vals):
        h = LatencyHistogram()
        for v in vals:
            h.record(v)
        hs.append(h)
    a, b, c = hs
    left = a.copy().merge(b).merge(c)
    right = a.copy().merge(b.copy().merge(c))
    assert left == right == LatencyHistogram.merged([a, b, c])


@settings(max_examples=40, deadline=None)
@given(values)
def test_histogram_dict_roundtrip(vals):
    h = LatencyHistogram()
    for v in vals:
        h.record(v)
    assert LatencyHistogram.from_dict(h.to_dict()) == h


def test_histogram_zeros_and_weighted():
    h = LatencyHistogram()
    h.record(0.0, 3)
    h.record(100.0, 7)
    assert h.count == 10
    assert h.percentile(10) == 0.0
    assert h.percentile(90) > 0.0
    h.record(50.0, 0)  # n <= 0 is a no-op
    assert h.count == 10


# --------------------------------------------------- Link stale-bucket prune

def test_link_stale_bucket_pruned_on_read():
    """Regression: a transfer from a front-end lagging below the prune
    horizon used to leave a bucket that only another transfer() would evict;
    a pure utilization() reader could see dead-epoch contention forever."""
    link = Link(CostModel())
    ep = link.epoch
    link.transfer(100 * ep, 4096)       # horizon at epoch 100
    link.transfer(5 * ep, 1 << 20)      # laggard writes below the prune floor
    assert 5 in link.bytes_in_epoch     # stale bucket is present...
    assert link.utilization(1000 * ep) == 0.0   # ...read advances the horizon
    assert 5 not in link.bytes_in_epoch  # ...and evicts it
    assert link.utilization(5 * ep + 1) == 0.0  # reader sees no ghost traffic


def test_link_reset_clears_horizon():
    link = Link(CostModel())
    link.transfer(100 * link.epoch, 4096)
    link.reset()
    assert link._hi_epoch == -1 and not link.bytes_in_epoch
    assert link.utilization(0.0) == 0.0


# ----------------------------------------------------- cluster stats/telemetry

def _tiny_cluster(n_blades=2):
    cluster = NVMCluster(n_blades=n_blades, n_shards=8)
    # rcb: the batched config drives doorbell read waves and write fences,
    # so traces cover every span type
    cfe = ClusterFrontEnd(cluster, FEConfig.rcb(cache_bytes=4096), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256)
    return cluster, cfe, t


def test_cluster_stats_and_telemetry():
    cluster, cfe, t = _tiny_cluster()
    pairs = [(i, i * 3) for i in range(120)]
    t.put_many(pairs)
    got = t.get_many([k for k, _ in pairs])
    assert got == [v for _, v in pairs]

    st_ = cfe.stats()
    assert set(st_["per_blade"]) == set(cluster.blades)
    # totals really are the per-blade sum (no rebind happened yet)
    some_key = "rdma_reads"
    assert st_["total"][some_key] == sum(
        snap[some_key] for snap in st_["per_blade"].values())

    tel = cfe.telemetry()
    assert tel["cluster_op_latency"]["put_many"]["count"] == len(pairs)
    assert tel["cluster_op_latency"]["get_many"]["count"] == len(pairs)
    assert tel["op_latency"]["get_many"]["count"] == len(pairs)
    for snap in tel["cluster_op_latency"].values():
        assert 0 < snap["p50"] <= snap["p99"] <= snap["p999"]
    assert tel["epoch"] == cluster.directory.epoch


def test_cluster_telemetry_survives_rebind():
    """Epoch bumps replace the per-blade FrontEnds; their histograms and
    counters must fold into the CFE accumulators, not vanish."""
    cluster, cfe, t = _tiny_cluster()
    t.put_many([(i, i) for i in range(100)])
    before = cfe.telemetry()["op_latency"]["put_many"]["count"]
    assert before == 100
    cluster.add_blade()
    rebalance(t)                     # migrations: revoke + epoch swap + rebind
    t.get_many(list(range(100)))
    tel = cfe.telemetry()
    assert tel["op_latency"]["put_many"]["count"] == 100   # retained
    assert tel["op_latency"]["get_many"]["count"] >= 100
    assert cfe.stats()["total"]["rdma_reads"] > 0


# ------------------------------------------------------------- trace schema

def test_trace_schema_and_nesting():
    try:
        with obs.observe(trace=True) as sess:
            cluster, cfe, t = _tiny_cluster()
            t.put_many([(i, i) for i in range(80)])
            cluster.add_blade()
            rebalance(t)
            assert t.get_many(list(range(80))) == list(range(80))
            doc = sess.tracer.to_chrome()
    finally:
        obs.stop()
    spans = report.spans(doc)
    assert spans, "trace has no spans"
    for e in spans:
        assert all(k in e for k in ("name", "ts", "dur", "pid", "tid"))
        assert e["dur"] >= 0
    assert report.validate(doc) == []       # spans nest / are disjoint per track
    names = report.span_names(doc)
    for required in ("read_wave", "flush", "lease_refresh", "lease_grant",
                     "migration", "op:put_many", "op:get_many"):
        assert names[required] > 0, f"missing {required} spans"
    assert len(report.blade_tracks(doc)) >= 2
    # a second session must start from a clean slate
    assert obs.session() is None


def test_tracing_off_costs_no_sim_time():
    """The same workload must land on the identical virtual clock with and
    without an active trace session (observability never perturbs the sim)."""
    def run():
        cluster, cfe, t = _tiny_cluster()
        t.put_many([(i, i) for i in range(150)])
        t.get_many(list(range(150)))
        return cfe.clock.now

    bare = run()
    try:
        with obs.observe(trace=True, metrics=True):
            traced = run()
    finally:
        obs.stop()
    assert traced == bare


# ------------------------------------------------------------ metrics export

def test_metrics_export(tmp_path):
    try:
        with obs.observe(trace=True, metrics=True) as sess:
            be = NVMBackend(capacity=1 << 22)
            fe = FrontEnd(be, FEConfig.rcb(cache_bytes=1 << 16))
            ht = RemoteHashTable(fe, "m", n_buckets=256)
            ht.put_many([(i, i) for i in range(200)])
            ht.get_many(list(range(200)))
            fe.drain(ht.h)
            obs.count("migrations", 2)
            prom = tmp_path / "m.prom"
            jpath = sess.export_metrics(str(prom))
    finally:
        obs.stop()
    text = prom.read_text()
    assert "# TYPE rnvm_fe_rdma_reads counter" in text
    assert "rnvm_migrations 2" in text
    assert 'rnvm_op_latency_ns{op="put_many",quantile="0.99"}' in text
    assert "rnvm_op_latency_ns_count" in text
    assert "rnvm_profile_seconds" in text   # wall-clock profile hooks fired
    data = json.loads(open(jpath).read())
    rows = data["histograms"]["op_latency_ns"]
    hist_ops = {r["labels"]["op"] for r in rows}
    assert {"put_many", "get_many"} <= hist_ops
    # the histogram buckets round-trip
    h0 = [r for r in rows if r["labels"].get("op") == "put_many"][0]
    assert LatencyHistogram.from_dict(h0["buckets"]).count == h0["count"]


def test_dead_frontends_fold_into_session(tmp_path):
    """Front-ends GC'd before export still contribute (weakref.finalize)."""
    import gc
    try:
        with obs.observe(metrics=True) as sess:
            def scoped():
                be = NVMBackend(capacity=1 << 22)
                fe = FrontEnd(be, FEConfig.rcb(cache_bytes=1 << 16))
                ht = RemoteHashTable(fe, "d", n_buckets=64)
                ht.put_many([(i, i) for i in range(50)])
                fe.drain(ht.h)
            scoped()
            gc.collect()
            totals, hists = sess.fe_totals()
    finally:
        obs.stop()
    assert totals.get("rdma_writes", 0) > 0
    assert hists["put_many"].count == 50
