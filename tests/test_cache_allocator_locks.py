"""Front-end substrate: hybrid cache policy, two-tier allocator, seqlock."""

import random

from repro.core import FEConfig, FrontEnd, NVMBackend, PageCache, WriterPreferredLock
from repro.core.structures import RemoteHashTable


def _drive(policy: str, accesses, size=64 * 100):
    c = PageCache(size, policy=policy, seed=1)
    for a in accesses:
        if c.get(a) is None:
            c.put(a, b"x" * 64)
    return c.miss_ratio


def test_hybrid_cache_between_rr_and_lru():
    """Paper §7.2: hybrid ~ LRU hit quality at ~RR cost.  On a zipf-like
    trace, hybrid's miss ratio must beat RR and be within range of LRU."""
    rng = random.Random(0)
    hot = list(range(80))
    cold = list(range(80, 4000))
    trace = [rng.choice(hot) if rng.random() < 0.8 else rng.choice(cold)
             for _ in range(20000)]
    m_lru = _drive("lru", trace)
    m_rr = _drive("rr", trace)
    m_hy = _drive("hybrid", trace)
    assert m_hy < m_rr
    assert m_hy < m_lru * 1.35  # close to LRU quality


def test_cache_eviction_respects_capacity():
    c = PageCache(10 * 64, policy="hybrid")
    for a in range(100):
        c.put(a, b"y" * 64)
    assert c.used_bytes <= 10 * 64
    assert len(c.pages) <= 10


def test_cache_write_through_update():
    c = PageCache(1024)
    c.put(0, b"a" * 16)
    c.update(0, 4, b"ZZ")
    assert bytes(c.get(0)) == b"aaaaZZaaaaaaaaaa"


def test_two_tier_allocator_reuse_and_reclaim():
    be = NVMBackend(capacity=1 << 22)
    fe = FrontEnd(be, FEConfig.rcb())
    al = fe.allocator
    addrs = [al.alloc(24) for _ in range(64)]
    fetched_before = al.slab_fetches
    for a in addrs:
        al.free(a)
    # refill reuses the retained empty slabs; only the slabs reclaimed to the
    # blade (beyond reclaim_threshold) need re-fetching
    addrs2 = [al.alloc(24) for _ in range(64)]
    assert al.slab_fetches <= fetched_before + (fetched_before - al.reclaim_threshold)
    assert len(set(addrs2)) == len(addrs2)


def test_allocator_size_classes_and_large():
    be = NVMBackend(capacity=1 << 22)
    fe = FrontEnd(be, FEConfig.rcb())
    a16 = fe.alloc(10)
    a32 = fe.alloc(30)
    assert a16 != a32
    big = fe.alloc(5000)  # > slab: direct contiguous backend allocation
    assert big % be.block_size == 0 or big >= be.heap_start


def test_writer_preferred_seqlock():
    be = NVMBackend(capacity=1 << 22)
    w = FrontEnd(be, FEConfig.rcb(), fe_id=0)
    r = FrontEnd(be, FEConfig.rcb(), fe_id=1)
    lock_w = WriterPreferredLock(w, "L")
    lock_r = WriterPreferredLock(r, "L")
    # writer holds -> reader sees odd SN and must wait; after release, even
    lock_w.writer_lock()
    sn = be.atomic_read(lock_w.addr)
    assert sn % 2 == 1
    lock_w.writer_unlock()
    sn0 = lock_r.reader_begin()
    assert sn0 % 2 == 0
    assert lock_r.reader_validate(sn0)
    # writer mutates between reader begin/validate -> reader must retry
    sn1 = lock_r.reader_begin()
    lock_w.writer_lock(); lock_w.writer_unlock()
    assert not lock_r.reader_validate(sn1)


def test_swmr_reader_sees_committed_data():
    be = NVMBackend(capacity=1 << 24)
    w = FrontEnd(be, FEConfig.rcb(batch_ops=16, oplog_group=4), fe_id=0)
    ht = RemoteHashTable(w, "h", n_buckets=32)
    for i in range(64):
        ht.put(i, i + 1)
    w.drain(ht.h)
    r = FrontEnd(be, FEConfig.rc(), fe_id=1)
    ht_r = RemoteHashTable(r, "h", create=False)
    assert all(ht_r.get(i) == i + 1 for i in range(64))
