"""Asymmetric state store: versioned commits, deltas, recovery, mirrors."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.statestore import AsymStore, CheckpointManager, FileBlade, MemoryBlade


@pytest.fixture(params=["memory", "file"])
def blade(request, tmp_path):
    if request.param == "memory":
        return MemoryBlade(mirrors=1)
    return FileBlade(str(tmp_path / "b0"), mirrors=[str(tmp_path / "m0")])


def test_commit_restore_roundtrip(blade):
    store = AsymStore(blade)
    mgr = CheckpointManager(store)
    state = {"w": jnp.arange(3000, dtype=jnp.float32),
             "b": jnp.ones((16,), jnp.bfloat16),
             "step": jnp.array(7, jnp.int32)}
    mgr.save_full(10, state)
    v, restored = mgr.restore(state)
    assert v == 10
    assert jnp.array_equal(restored["w"], state["w"])
    assert restored["b"].dtype == jnp.bfloat16
    assert int(restored["step"]) == 7


def test_root_swap_is_atomic_ordering(blade):
    """Objects then manifest then root: a version is visible only complete."""
    store = AsymStore(blade)
    mgr = CheckpointManager(store)
    state = {"w": jnp.zeros(10)}
    assert store.latest_version() == 0
    mgr.save_full(5, state)
    assert store.latest_version() == 5
    assert store.manifest(5)["tensors"]["w"]["kind"] == "full"


def test_delta_commit_and_error_feedback(blade):
    store = AsymStore(blade)
    mgr = CheckpointManager(store, delta_topk_frac=0.05)
    w0 = jnp.zeros(4096, jnp.float32)
    mgr.save_full(1, {"w": w0})
    # sparse change fully captured by top-k
    w1 = w0.at[jnp.arange(0, 4096, 100)].set(3.0)
    mgr.save_delta(2, {"w": w1})
    _, r = mgr.restore({"w": w1}, version=2)
    np.testing.assert_allclose(np.asarray(r["w"]), np.asarray(w1), atol=1e-6)
    # dense change: lossy now, but error feedback catches up over commits
    w2 = w1 + 0.01
    mgr.save_delta(3, {"w": w2})
    for step in range(4, 10):
        mgr.save_delta(step, {"w": w2})
    _, r2 = mgr.restore({"w": w2}, version=9)
    err = float(jnp.max(jnp.abs(r2["w"] - w2)))
    assert err < 0.011  # strictly shrinking residual


def test_resume_plan_and_step_logs(blade):
    store = AsymStore(blade)
    mgr = CheckpointManager(store)
    mgr.save_full(10, {"w": jnp.zeros(4)})
    for s in (10, 11, 12):
        mgr.log_step(s)
    mgr.save_delta(12, {"w": jnp.ones(4)})  # delta versions are not exact
    full_v, pending = mgr.resume_plan()
    assert full_v == 10
    assert [p["step"] for p in pending] == [11, 12]


def test_gc_keeps_delta_bases(blade):
    store = AsymStore(blade)
    mgr = CheckpointManager(store, keep=1)
    mgr.save_full(1, {"w": jnp.zeros(64)})
    mgr.save_delta(2, {"w": jnp.ones(64)})
    store.gc(keep=1)
    assert 1 in store.committed_versions()  # base of kept delta survives
    _, r = mgr.restore({"w": jnp.ones(64)}, version=2)


def test_mirror_has_everything(blade):
    store = AsymStore(blade)
    mgr = CheckpointManager(store)
    mgr.save_full(3, {"w": jnp.arange(100.0)})
    mgr.log_step(3)
    mirror = blade.mirrors[0]
    mstore = AsymStore(mirror)
    assert mstore.latest_version() == 3
    np.testing.assert_array_equal(mstore.read_tensor(3, "w")[0], np.arange(100.0))
    assert [s for s, _ in mirror.scan_log()] == [1]


def test_file_blade_torn_log_and_corrupt_object(tmp_path):
    b = FileBlade(str(tmp_path / "b"))
    b.append(b"one")
    b.append(b"two")
    with open(os.path.join(str(tmp_path / "b"), "log", "oplog.bin"), "ab") as f:
        f.write(b"\xff\xff\xff\xffgarbage")
    b2 = FileBlade(str(tmp_path / "b"))
    assert [p for _, p in b2.scan_log()] == [b"one", b"two"]
    # object corruption detected by checksum
    b2.put("obj", b"payload")
    path = b2._obj_path("obj")
    raw = bytearray(open(path, "rb").read())
    raw[-1] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        b2.get("obj")


def test_elastic_restore_dtype_cast(blade):
    """Restore may target different dtypes/shardings than the saver used."""
    store = AsymStore(blade)
    mgr = CheckpointManager(store)
    mgr.save_full(1, {"w": jnp.arange(64, dtype=jnp.float32)})
    tmpl = {"w": jnp.zeros(64, jnp.bfloat16)}
    _, r = mgr.restore(tmpl)
    assert r["w"].dtype == jnp.bfloat16
