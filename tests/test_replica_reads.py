"""Replica-aware read path: mirror read routing, bounded staleness,
directory leases, and revoke-before-swap (PR 5).

The contract under test:

  * mirror endpoints serve byte-identical data when replication is
    synchronous (the default), and never data older than the advertised
    staleness bound when it lags;
  * read-your-writes survives replica routing: keys a front-end wrote are
    pinned to the primary until the mirrors' applied watermark provably
    covers the write;
  * a front-end holding a directory lease validates locally — and every
    reconfiguration revokes outstanding leases BEFORE swapping the mapping,
    so no lease holder ever reads a tombstoned source.
"""

import random

import pytest

from repro.cluster import (
    ClusterFrontEnd,
    LeaseTable,
    NVMCluster,
    ReadPolicy,
    ShardedHashTable,
    migrate_shard,
    rebalance,
)
from repro.core import CrashError, FEConfig, FrontEnd, NVMBackend
from repro.core.structures import RemoteHashTable

try:
    from hypothesis import given, settings, strategies as st
except Exception:  # pragma: no cover - container without hypothesis
    from _hypothesis_shim import given, settings, strategies as st


def _mk_cluster(n_blades=2, n_shards=8, **kw):
    return NVMCluster(n_blades=n_blades, n_shards=n_shards,
                      capacity_per_blade=1 << 25, **kw)


# ------------------------------------------------------------- byte identity
def test_mirror_reads_byte_identical_to_primary():
    """With synchronous replication (default), a replica-routed read
    returns exactly the primary's bytes — for every byte of the arena."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=2)
    fe = FrontEnd(be, FEConfig.rcb(cache_bytes=4096))
    ht = RemoteHashTable(fe, "h", n_buckets=256)
    rng = random.Random(3)
    model = {}
    for _ in range(600):
        k = rng.randrange(250)
        if rng.random() < 0.75:
            v = rng.randrange(1 << 30)
            ht.put(k, v)
            model[k] = v
        else:
            ht.delete(k)
            model.pop(k, None)
    fe.drain(ht.h)
    for idx in range(2):
        assert bytes(be.mirrors[idx].arena) == bytes(be.arena)
    # replica-routed reads return the same values the primary serves
    with fe.replica_reads(ReadPolicy(mode="mirror", max_staleness_ops=0)):
        got = ht.get_many(sorted(model))
    assert got == [model[k] for k in sorted(model)]
    assert fe.stats.replica_reads > 0
    assert fe.stats.replica_fallbacks == 0


def test_promoted_blade_mirrors_serve_replica_reads():
    """promote_mirror must re-seed the fresh blade's own mirror set: a
    fresh empty mirror receiving only post-promotion deltas would advertise
    lag 0 (its seq-slot copy updates) while holding none of the data."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    fe = FrontEnd(be, FEConfig(use_oplog=True, use_cache=False, use_batch=False))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    for k in range(50):
        ht.put(k, k * 2)
    fe.drain(ht.h)
    promoted = be.promote_mirror(0)
    assert bytes(promoted.mirrors[0].arena) == bytes(promoted.arena)
    fe2 = FrontEnd(promoted, FEConfig(use_oplog=True, use_cache=False,
                                      use_batch=False), fe_id=1)
    ht2 = RemoteHashTable.recover(fe2, "h")
    ht2.put(99, 7)
    fe2.drain(ht2.h)
    with fe2.replica_reads(ReadPolicy(mode="mirror", max_staleness_ops=0)):
        got = [ht2.get(k) for k in range(50)] + [ht2.get(99)]
    assert got == [k * 2 for k in range(50)] + [7]
    assert fe2.stats.replica_reads > 0


def test_lagging_replica_bytes_never_enter_the_cache():
    """Bytes fetched from a lagging mirror must not pollute the front-end
    page cache: the cache outlives the policy scope, and a later
    primary-routed read hitting them would extend staleness past the
    contract."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    fe = FrontEnd(be, FEConfig.rc())  # cache on, serial reads
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    for k in range(30):
        ht.put(k, k)
    fe.drain(ht.h)
    be.mirrors[0].lag_writes = 1 << 30  # freeze replication
    for k in range(30):
        ht.put(k, k + 1000)  # stale values now live only on the mirror
    fe.drain(ht.h)
    fe.cache.clear()  # drop write-through entries: force remote reads
    with fe.replica_reads(ReadPolicy(mode="mirror", max_staleness_ops=1 << 40)):
        stale = [ht.get(k) for k in range(30)]
    assert stale == list(range(30))  # bounded-stale values, as contracted
    # out of policy scope, primary reads must see the fresh values — a
    # cached stale byte would leak them here
    assert [ht.get(k) for k in range(30)] == [k + 1000 for k in range(30)]


def test_replica_read_does_not_require_live_primary():
    """A mirror is its own physical blade: replica reads keep working after
    the primary crashes (the read-side availability win)."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    fe = FrontEnd(be, FEConfig(use_oplog=True, use_cache=False, use_batch=False))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    for k in range(50):
        ht.put(k, k * 2)
    fe.drain(ht.h)
    be.crash()
    with fe.replica_reads(ReadPolicy(mode="mirror", max_staleness_ops=0)):
        assert ht.get(7) == 14
    with pytest.raises(CrashError):
        ht.get(7)  # primary routing still faults


# --------------------------------------------------------- bounded staleness
def _unique_value_workload(lag_writes: int, bound: int, ops: int, seed: int):
    """Interleave writes (globally unique values) with replica-routed point
    reads against a mirror lagging `lag_writes` physical writes; check every
    replica-served value against the per-key version history."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    be.mirrors[0].lag_writes = lag_writes
    # serial config, per-op flush: the applied watermark advances op by op,
    # so the bound check is exercised at its finest granularity
    fe = FrontEnd(be, FEConfig(use_oplog=True, use_cache=False, use_batch=False,
                               oplog_pipeline=1))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    policy = ReadPolicy(mode="mirror", max_staleness_ops=bound)
    rng = random.Random(seed)
    history = {}        # key -> list of (write seq, value)
    value_seq = {}      # unique value -> seq of the write that produced it
    next_value = 1
    violations = []
    for _ in range(ops):
        k = rng.randrange(16)
        if rng.random() < 0.6 or k not in history:
            ht.put(k, next_value)
            history.setdefault(k, []).append((ht.h.seq, next_value))
            value_seq[next_value] = ht.h.seq
            next_value += 1
            continue
        committed = ht.h.seq
        applied = be.replica_applied_seq("h")
        before = fe.stats.replica_fallbacks
        with fe.replica_reads(policy):
            got = ht.get(k)
        served_by_replica = fe.stats.replica_fallbacks == before
        if served_by_replica:
            # THE contract: a replica never serves past the bound
            if committed - applied > bound:
                violations.append(("bound", k, committed, applied))
                continue
            # value-level consistency: the mirror cut fully reflects ops
            # <= applied - 1 and nothing past op `applied`, so the served
            # value must lie between k's last write at or below applied-1
            # (the freshness floor) and its last write at or below applied
            floor = [s for s, _ in history[k] if s <= applied - 1]
            if got is None:
                ok = not floor
            else:
                ok = (got in value_seq
                      and value_seq[got] <= applied
                      and (not floor or value_seq[got] >= max(floor)))
            if not ok:
                violations.append(("value", k, got, committed, applied))
        else:
            # primary fallback serves the freshest committed value
            if got != history[k][-1][1]:
                violations.append(("primary", k, got, committed))
    return violations, fe


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=40),
       st.integers(min_value=0, max_value=30),
       st.integers(min_value=0, max_value=999))
def test_replica_reads_never_exceed_staleness_bound(lag, bound, seed):
    violations, _ = _unique_value_workload(lag, bound, ops=120, seed=seed)
    assert not violations, violations


def test_over_lag_mirror_falls_back_to_primary():
    """A mirror further behind than the bound never serves: every read falls
    back to the primary and returns the freshest value."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    be.mirrors[0].lag_writes = 10_000  # never catches up mid-run
    fe = FrontEnd(be, FEConfig(use_oplog=True, use_cache=False, use_batch=False,
                               oplog_pipeline=1))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    policy = ReadPolicy(mode="mirror", max_staleness_ops=3)
    for k in range(40):
        ht.put(k, k + 100)
    with fe.replica_reads(policy):
        got = [ht.get(k) for k in range(40)]
    assert got == [k + 100 for k in range(40)]
    assert fe.stats.replica_reads == 0
    assert fe.stats.replica_fallbacks > 0


# ----------------------------------------------------- read-your-writes pins
def test_read_your_writes_under_lease_with_lagging_mirrors():
    """Keys written by this front-end read back their own writes through the
    replica policy even when every mirror lags arbitrarily: pins hold them
    on the primary until the mirror watermark provably covers the write."""
    cluster = _mk_cluster(n_blades=2, num_mirrors=1)
    for be in cluster.blades.values():
        for m in be.mirrors:
            m.lag_writes = 1 << 30  # mirrors effectively frozen
    policy = ReadPolicy(mode="auto", max_staleness_ops=1 << 40)  # no bound
    cfe = ClusterFrontEnd(cluster, FEConfig.rcb(cache_bytes=4096), fe_id=0)
    ht = ShardedHashTable(cfe, "ht", read_policy=policy)
    rng = random.Random(9)
    model = {}
    for round_ in range(6):
        pairs = [(rng.randrange(1 << 16), round_ * 1000 + j) for j in range(80)]
        ht.put_many(pairs)
        for k, v in pairs:
            model[k] = v
        keys = [k for k, _ in pairs]
        assert ht.get_many(keys) == [model[k] for k in keys]  # immediate RYW
        assert ht.get(keys[0]) == model[keys[0]]
    # the frozen mirrors must never have served these keys
    assert all(k in ht._pinned for k in model)
    # once the mirrors catch up, pins release and replicas serve
    for be in cluster.blades.values():
        for m in be.mirrors:
            m.lag_writes = 0
            m.sync()
    ht.drain()
    keys = sorted(model)
    assert ht.get_many(keys) == [model[k] for k in keys]
    stats = cfe.aggregate_stats()
    assert stats["replica_reads"] > 0
    assert not ht._pinned  # every pin released by the watermark


def test_read_your_writes_survives_migration_with_lagging_dst_mirror():
    """Pin seqs are recorded against the source shard's op stream; after a
    migration the destination renumbers every op, so pins must be rebased
    at rebind — comparing a source seq to the destination watermark would
    wrongly release pins and serve this front-end's own writes from a
    lagging destination mirror."""
    cluster = _mk_cluster(n_blades=2, n_shards=8, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=1 << 40)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    ht = ShardedHashTable(cfe, "ht", read_policy=policy)
    model = {}
    for k in range(600):
        ht.put(k, k + 50)
        model[k] = k + 50
    ht.drain()

    shard = 0
    dst = cluster.add_blade()
    # the destination blade's mirror never applies anything
    for m in cluster.blades[dst].mirrors:
        m.lag_writes = 1 << 30
    migrate_shard(ht, shard, dst)
    # every write this front-end made must still read back, pinned to the
    # destination primary (its mirror holds nothing)
    assert [ht.get(k) for k in sorted(model)] == [model[k] for k in sorted(model)]
    keys = sorted(model)
    assert ht.get_many(keys) == [model[k] for k in keys]


def test_no_mirror_cluster_records_no_pins():
    """Pins exist to keep replica reads correct; a cluster with no mirrors
    can never serve a replica read, so writes must not accumulate pin
    state."""
    cluster = _mk_cluster(n_blades=2, num_mirrors=0)
    policy = ReadPolicy(mode="auto", max_staleness_ops=64)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=0)
    ht = ShardedHashTable(cfe, "ht", read_policy=policy)
    for k in range(500):
        ht.put(k, k)
    ht.put_many([(k, k) for k in range(500, 700)])
    assert not ht._pinned
    assert ht.get_many(list(range(700))) == list(range(700))


# ------------------------------------------------------------------- leases
def test_lease_validates_locally_and_renews_on_expiry():
    cluster = _mk_cluster(n_blades=2, lease_ttl_ns=50_000.0)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=0)
    ht = ShardedHashTable(cfe, "ht")
    for k in range(120):
        ht.put(k, k)
    ht.drain()
    assert cfe.lease_validations > 0
    # epoch never moved, yet the tiny TTL forced periodic renewal fetches
    assert cluster.directory.epoch == 0
    assert cfe.directory_fetches > 1
    # a roomy TTL pays exactly one fetch for the same workload
    cluster2 = _mk_cluster(n_blades=2, lease_ttl_ns=1e12)
    cfe2 = ClusterFrontEnd(cluster2, FEConfig.rc(), fe_id=0)
    ht2 = ShardedHashTable(cfe2, "ht")
    for k in range(120):
        ht2.put(k, k)
    ht2.drain()
    assert cfe2.directory_fetches == 1
    assert cfe2.lease_validations > 100


def test_lease_table_roundtrip_and_bootstrap():
    t = LeaseTable()
    t.grant(0, 3, 1000.0, 500.0)
    t.grant(7, 3, 2000.0, 500.0)
    raw = t.encode()
    t2 = LeaseTable.decode(raw)
    assert t2 is not None and t2.leases == t.leases
    broken = bytearray(raw)
    broken[5] ^= 0x10
    assert LeaseTable.decode(bytes(broken)) is None
    # persisted on every live blade; bootstrap recovers from any survivor
    cluster = _mk_cluster(n_blades=3)
    t.persist(cluster.blades)
    cluster.blades[0].crash()
    got = LeaseTable.bootstrap(cluster.blades)
    assert got.leases == t.leases


def test_migration_revokes_lease_before_swap():
    """A second front-end validating locally under its lease must fault and
    refresh after a migration — never read the tombstoned (and reclaimed)
    source copy."""
    cluster = _mk_cluster(n_blades=2, n_shards=8)
    cfe_a = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=0)
    cfe_b = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=1)
    ht_a = ShardedHashTable(cfe_a, "ht")
    ht_b = ShardedHashTable(cfe_b, "ht")
    model = {}
    for k in range(300):
        ht_a.put(k, k * 3)
        model[k] = k * 3
    ht_a.drain()
    # B reads through its own lease and binds the source blade
    assert all(ht_b.get(k) == model[k] for k in range(0, 300, 17))
    assert cluster.leases.valid(cfe_b.fe_id, cfe_b.epoch, cfe_b.clock.now)

    shard = 3
    dst = cluster.add_blade()
    epoch_before = cfe_b.epoch
    migrate_shard(ht_a, shard, dst)
    # the swap revoked B's lease BEFORE flipping the assignment
    assert not cluster.leases.valid(cfe_b.fe_id, cfe_b.epoch, cfe_b.clock.now)
    fetches_before = cfe_b.directory_fetches
    # B's next ops must re-fetch, rebind, and route to the destination —
    # the source copy is destroyed, so stale routing would misread
    assert all(ht_b.get(k) == v for k, v in model.items())
    assert cfe_b.epoch > epoch_before
    assert cfe_b.epoch == cluster.directory.epoch
    assert cfe_b.directory.blade_of(shard) == dst
    assert cfe_b.directory_fetches > fetches_before


def test_failover_revokes_lease_before_promotion_swap():
    """Mirror promotion revokes every lease before swapping the fresh blade
    in: a stale front-end transparently refreshes, and replica-routed reads
    keep returning every committed value."""
    cluster = _mk_cluster(n_blades=2, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=256)
    cfe_a = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=0)
    cfe_b = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=1)
    ht_a = ShardedHashTable(cfe_a, "ht")
    ht_b = ShardedHashTable(cfe_b, "ht", read_policy=policy)
    model = {}
    for k in range(240):
        ht_a.put(k, k + 5)
        model[k] = k + 5
    ht_a.drain()
    assert ht_b.get(11) == 16  # B holds a lease now

    cluster.blades[1].fail_permanently()
    # A notices first and performs the promotion (epoch bump + revocation)
    for k in range(240, 320):
        ht_a.put(k, k + 5)
        model[k] = k + 5
    ht_a.drain()
    assert cluster.failovers == 1
    assert not cluster.leases.valid(cfe_b.fe_id, cfe_b.epoch, cfe_b.clock.now)
    # B refreshes on its next op and reads everything, replicas included
    keys = sorted(model)
    assert ht_b.get_many(keys) == [model[k] for k in keys]
    assert cfe_b.epoch == cluster.directory.epoch
    assert cluster.failovers == 1  # no duplicate promotion


# --------------------------------------------------------- weighted rebalance
def test_rebalance_weighs_per_shard_op_counts():
    """Two hot shards must not stay colocated after scale-out: the weighted
    rebalancer evens *load*, not raw shard counts."""
    cluster = _mk_cluster(n_blades=2, n_shards=8)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(), fe_id=0)
    ht = ShardedHashTable(cfe, "ht")
    model = {}
    keyspace = list(range(4000))
    for k in keyspace[:400]:
        ht.put(k, k)
        model[k] = k
    ht.drain()
    directory = cluster.directory
    # hammer the two shards of one blade hottest
    hot_blade = 0
    hot_shards = directory.shards_on(hot_blade)[:2]
    hot_keys = [k for k in keyspace if directory.shard_of(k) in hot_shards][:40]
    for _ in range(20):
        for k in hot_keys:
            if k in model:
                assert ht.get(k) == model[k]
            else:
                ht.put(k, k)
                model[k] = k
    w_hot = [directory.shard_weight(s) for s in hot_shards]
    assert min(w_hot) > 3 * max(
        directory.shard_weight(s) for s in range(8) if s not in hot_shards
    )
    cluster.add_blade()
    moves = rebalance(ht)
    assert moves, "scale-out must migrate shards"
    # terminal guarantee of the greedy: no remaining move strictly improves
    weights = {b: w for b, w in directory.load_weights().items()}
    hi = max(weights, key=lambda b: (weights[b], b))
    lo = min(weights, key=lambda b: (weights[b], b))
    gap = weights[hi] - weights[lo]
    assert all(directory.shard_weight(s) >= gap for s in directory.shards_on(hi))
    # the two hot shards ended up on different blades
    assert len({directory.blade_of(s) for s in hot_shards}) == 2
    # and nothing was lost on the way
    assert sorted(ht.items()) == sorted(model.items())


# ------------------------------------------------------- naive doorbell waves
def test_naive_multi_location_op_posts_one_write_wave():
    """The naive variant's per-location posted writes share one doorbell:
    one wave per op, every location a WQE, completion fenced once."""
    be = NVMBackend(capacity=1 << 24)
    fe = FrontEnd(be, FEConfig.naive())
    ht = RemoteHashTable(fe, "h", n_buckets=32)
    for k in range(60):
        ht.put(k, k)  # most ops touch >= 2 locations (node + bucket head)
    assert fe.stats.write_waves == 60
    assert fe.stats.wqe_posts == fe.stats.rdma_writes
    assert fe.stats.wqe_posts > fe.stats.write_waves  # real batching happened


# ------------------------------------------------------- mirror-routed scans
def test_items_scan_routes_to_mirrors_under_policy():
    """A whole-structure scan fans out its leaf reads to mirror endpoints
    under the read policy — the scan's read wave hits replica arenas, not
    the primary — and still returns exactly the written contents."""
    cluster = _mk_cluster(n_blades=2, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=1 << 40)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    ht = ShardedHashTable(cfe, "ht", read_policy=policy)
    model = {k: k * 3 + 1 for k in range(400)}
    ht.put_many(sorted(model.items()))
    ht.drain()  # synchronous mirrors: watermarks cover every write
    before = cfe.aggregate_stats()["replica_reads"]
    assert sorted(ht.items()) == sorted(model.items())
    assert cfe.aggregate_stats()["replica_reads"] > before
    assert not ht._pinned  # the scan released every covered pin


def test_scan_with_fresh_pins_stays_on_primary():
    """A scan touches every key, so one unreleased pin (a local write not
    yet provably applied on any mirror) keeps that shard's whole scan on
    the primary — no replica read may serve a scan that could miss this
    front-end's own writes."""
    cluster = _mk_cluster(n_blades=2, num_mirrors=1)
    for be in cluster.blades.values():
        for m in be.mirrors:
            m.lag_writes = 1 << 30  # mirrors frozen: pins never release
    policy = ReadPolicy(mode="auto", max_staleness_ops=1 << 40)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    ht = ShardedHashTable(cfe, "ht", read_policy=policy)
    model = {k: k + 7 for k in range(300)}
    ht.put_many(sorted(model.items()))
    assert sorted(ht.items()) == sorted(model.items())  # RYW via primary
    assert cfe.aggregate_stats()["replica_reads"] == 0
    # once mirrors catch up, the same scan is free to leave the primary
    for be in cluster.blades.values():
        for m in be.mirrors:
            m.lag_writes = 0
            m.sync()
    ht.drain()
    assert sorted(ht.items()) == sorted(model.items())
    assert cfe.aggregate_stats()["replica_reads"] > 0


def test_range_scan_routes_to_mirrors_under_policy():
    """range_scan's per-shard leaf-chain walks route through the same
    mirror read waves and merge to a globally sorted, correct result."""
    from repro.cluster import ShardedBPTree

    cluster = _mk_cluster(n_blades=2, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=1 << 40)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    bt = ShardedBPTree(cfe, "bt", read_policy=policy)
    model = {k: k * 5 for k in range(0, 900, 3)}
    for k, v in model.items():
        bt.insert(k, v)
    bt.drain()
    before = cfe.aggregate_stats()["replica_reads"]
    want = sorted((k, v) for k, v in model.items() if 100 <= k <= 700)
    assert bt.range_scan(100, 700) == want
    assert cfe.aggregate_stats()["replica_reads"] > before
    assert bt.items() == sorted(model.items())
