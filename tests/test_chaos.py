"""Fault injection + self-healing path (chaos harness).

What must hold:

  * the durability oracle over seeded random fault schedules — every acked
    op survives recovery, unacked ops land whole or not at all, the healed
    state matches a fault-free replay of the acked prefix;
  * the data path heals itself: deadlines + bounded retries absorb
    transient drops, the per-link breaker trips on a persistently
    unreachable blade, and the front-end fences + promotes the mirror with
    NO test-orchestrated failover call;
  * a tear landing exactly on the 8-byte seq-watermark write commits the
    group or erases it — never a torn middle (targeted
    ``schedule_torn_write``);
  * the PR 5 staleness/RYW contract survives mirror lag spikes injected
    mid-run, and lagging-mirror bytes stay out of the page cache;
  * a cold re-attach replays a committed-but-unapplied op-log tail on
    FIRST touch (crash -> reboot -> rejoin end to end).
"""

import random

import pytest

from repro import obs
from repro.cluster import ClusterFrontEnd, NVMCluster, ReadPolicy, ShardedHashTable
from repro.core import (CircuitBreaker, CrashError, EndpointUnreachable,
                        FEConfig, FrontEnd, NVMBackend)
from repro.core.structures import RemoteHashTable
from repro.faults import (ALL_FAULT_KINDS, FaultInjector, FaultPlan,
                          run_chaos_schedule, run_steal_schedule)
from repro.faults.harness import _stale_epoch_total

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the seeded-random shim
    from _hypothesis_shim import given, settings, st


DURABLE = dict(cache_bytes=4096, oplog_pipeline=1)


# ------------------------------------------------------------ chaos sweeps
def test_chaos_sweep_all_fault_classes():
    """Seeded random schedules over every fault class pass the durability
    oracle (the benchmark runs the full 200-schedule sweep; this keeps a
    representative slice in tier-1)."""
    seen = set()
    for seed in range(30):
        r = run_chaos_schedule(seed)
        assert r.ok, f"seed {seed}: {r.violations[:5]}"
        seen.update(r.injected)
    # the sweep must genuinely exercise the fault surface, not no-op
    assert len(seen) >= 9, f"only {sorted(seen)} injected"


def test_chaos_single_fault_classes():
    """Each fault class alone passes the oracle (failures localize)."""
    for kind in ALL_FAULT_KINDS:
        r = run_chaos_schedule(7, kinds=[kind], n_faults=4)
        assert r.ok, f"kind {kind}: {r.violations[:5]}"


def test_chaos_reports_fault_mix_and_heals():
    r = run_chaos_schedule(3, ensure=("nic_dead", "crash"))
    assert r.ok, r.violations[:5]
    assert r.injected.get("nic_dead", 0) >= 1
    assert r.injected.get("crash", 0) >= 1
    # nic_dead is unreachable-forever: healing requires a promotion that
    # was initiated by the data path, not the test
    assert r.promotions >= 1
    assert r.failovers_initiated >= 1


# ----------------------------------------- self-healing: retries & breaker
def test_wqe_drops_absorbed_by_bounded_retries():
    """Drops below the breaker threshold cost timeouts + backoff on the sim
    clock and the op still acks; nothing escapes to the caller."""
    be = NVMBackend(capacity=1 << 22)
    fe = FrontEnd(be, FEConfig.rc(**DURABLE))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    ht.put(1, 1)
    fe.drain(ht.h)
    t0 = fe.clock.now
    be.link.inject().drop_pending = 2
    ht.put(2, 2)
    assert fe.stats.op_timeouts == 2
    assert fe.stats.op_retries == 2
    assert fe.stats.breaker_trips == 0
    # each lost completion charges the full deadline before the resend
    assert fe.clock.now - t0 >= 2 * fe.cost.op_timeout_ns
    assert ht.get(2) == 2


def test_breaker_trips_and_fails_fast():
    """Consecutive timeouts past the threshold open the breaker; further
    rounds fail fast with EndpointUnreachable until the cooldown."""
    be = NVMBackend(capacity=1 << 22)
    fe = FrontEnd(be, FEConfig.rc(**DURABLE))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    ht.put(1, 1)
    be.link.inject().drop_pending = 1 << 30
    with pytest.raises(EndpointUnreachable):
        ht.put(2, 2)
    assert fe.stats.breaker_trips == 1
    assert be.link.breaker.state == "open"
    # fail-fast: no further timeout charged while open
    timeouts = fe.stats.op_timeouts
    with pytest.raises(EndpointUnreachable):
        ht.put(3, 3)
    assert fe.stats.op_timeouts == timeouts
    # cooldown elapses -> half-open -> a clean round closes it
    be.link.fault.drop_pending = 0
    fe.clock.advance(fe.cost.breaker_cooldown_ns)
    ht.put(4, 4)
    assert be.link.breaker.state == "closed"
    assert ht.get(4) == 4
    # the unacked puts are allowed either outcome; acked state must hold
    assert ht.get(1) == 1
    assert ht.get(2) in (None, 2)
    assert ht.get(3) in (None, 3)


def test_retry_backoff_is_deterministic():
    """Same seed/config twice -> identical sim-time trajectory (jitter is
    hashed from sim state, never wall-clock random)."""
    def run():
        be = NVMBackend(capacity=1 << 22)
        fe = FrontEnd(be, FEConfig.rc(**DURABLE))
        ht = RemoteHashTable(fe, "h", n_buckets=64)
        ht.put(1, 1)
        be.link.inject().drop_pending = 3
        try:
            ht.put(2, 2)
        except CrashError:
            pass
        return fe.clock.now, fe.stats.op_retries
    assert run() == run()


# ------------------------------------- front-end-initiated auto-promotion
def test_data_path_initiates_promotion_on_unreachable_primary():
    """A blade that stops answering (alive, NIC dead) is fenced and its
    mirror promoted BY THE DATA PATH: no test code calls crash(),
    fail_permanently(), promote_blade(), or handle_blade_failure()."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256)
    model = {}
    for k in range(60):
        t.put(k, k)
        model[k] = k
    t.drain()
    victim = 1
    cluster.blades[victim].link.inject().drop_pending = 1 << 30  # NIC dies
    for k in range(60, 90):  # ops keep flowing; some hit the sick blade
        t.put(k, k)
        model[k] = k
    assert cluster.failovers >= 1
    assert cfe.failovers_initiated >= 1
    assert cluster.blades[victim].alive  # promoted replacement serves
    got = t.get_many(sorted(model))
    assert got == [model[k] for k in sorted(model)]


def test_transient_breaker_open_heals_without_promotion():
    """A breaker opened by a burst of drops on an otherwise-healthy blade
    is probed and reset by recover_blade — no fencing, no promotion."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256)
    for k in range(40):
        t.put(k, k)
    t.drain()
    # exactly enough drops to trip the breaker, none left for the probe
    cluster.blades[1].link.inject().drop_pending = 3
    for k in range(40, 60):
        t.put(k, k)
    assert cluster.failovers == 0
    assert cfe.failovers_initiated == 0
    assert t.get_many(list(range(60))) == list(range(60))


# ----------------------------------------------- torn watermark regression
def _armed_table():
    be = NVMBackend(capacity=1 << 22)
    fe = FrontEnd(be, FEConfig.rc(**DURABLE))
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    for k in range(10):
        ht.put(k, k)
    fe.drain(ht.h)
    return be, fe, ht


def _put_through_power_loss(be, ht, k, v):
    """Issue a put whose flush dies at the armed tear; the blade may die
    after the put's last WQE, so the caller sees either an ack or a crash."""
    try:
        ht.put(k, v)
    except CrashError:
        pass
    assert not be.alive  # the tear fired


def test_tear_on_watermark_keep0_erases_the_group():
    """keep_bytes < 8 on the watermark slot: the commit record never
    persists, so recovery must treat the whole flushed group as unwritten
    — the acked prefix survives, the torn group vanishes."""
    be, fe, ht = _armed_table()
    be.schedule_torn_write(0, at_name="h.seq")
    _put_through_power_loss(be, ht, 99, 99)
    be.reboot()
    fe2 = FrontEnd(be, FEConfig.rc(**DURABLE))
    ht2 = RemoteHashTable.recover(fe2, "h")
    assert ht2.get(99) is None
    assert [ht2.get(k) for k in range(10)] == list(range(10))


def test_tear_on_watermark_keep8_commits_the_group():
    """keep_bytes >= 8 on the watermark slot: the 8-byte commit record
    lands whole before the power loss, so recovery must replay the group
    even though the writer never saw the completion."""
    be, fe, ht = _armed_table()
    be.schedule_torn_write(8, at_name="h.seq")
    _put_through_power_loss(be, ht, 99, 99)
    be.reboot()
    fe2 = FrontEnd(be, FEConfig.rc(**DURABLE))
    ht2 = RemoteHashTable.recover(fe2, "h")
    assert ht2.get(99) == 99
    assert [ht2.get(k) for k in range(10)] == list(range(10))


def test_watermark_tear_is_persist_atomic_either_way():
    """No torn middle: after a tear targeted at the watermark, the slot
    holds either the old seq or the new seq — never a partial value."""
    for keep in (0, 3, 7, 8):
        be, fe, ht = _armed_table()
        old = be.get_name("h.seq")
        be.schedule_torn_write(keep, at_name="h.seq")
        _put_through_power_loss(be, ht, 99, 99)
        # inspect the persisted arena bytes directly: the blade is down
        got = int.from_bytes(
            be.arena[be.name_slot_addr("h.seq"):
                     be.name_slot_addr("h.seq") + 8], "little")
        if keep >= 8:
            assert got > old, f"keep={keep}: watermark should have landed"
        else:
            assert got == old, f"keep={keep}: watermark should not move"


def test_untargeted_tear_still_cuts_mid_entry():
    """The counter form keeps its historical semantics: a tear landing in
    a multi-word write persists exactly keep_bytes bytes."""
    be = NVMBackend(capacity=1 << 22)
    be.schedule_torn_write(5)
    be.write(be.heap_start, b"\xaa" * 16)
    assert not be.alive
    assert bytes(be.arena[be.heap_start:be.heap_start + 16]) == \
        b"\xaa" * 5 + b"\x00" * 11


def test_cancel_torn_write_disarms():
    be = NVMBackend(capacity=1 << 22)
    be.set_name("x", 1)
    be.schedule_torn_write(0, at_name="x")
    be.cancel_torn_write()
    be.set_name("x", 7)
    assert be.alive
    assert be.get_name("x") == 7


# ----------------------------------- staleness contract under lag spikes
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=200),
       st.integers(min_value=0, max_value=999))
def test_lag_spike_mid_run_never_violates_ryw_pins(spike, seed):
    """Inject a mirror lag spike in the middle of a replica-routed
    read/write mix: read-your-writes must hold for every key this client
    wrote (the pins keep lagging replicas out of the read path)."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=8)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256, read_policy=policy)
    rng = random.Random(seed)
    model = {}
    pairs = [(k, k) for k in range(48)]
    t.put_many(pairs)
    model.update(pairs)
    for step in range(12):
        if step == 5:  # mid-run spike on every blade's mirror
            for be in cluster.blades.values():
                be.mirrors[0].set_lag(spike)
        ks = [rng.randrange(64) for _ in range(16)]
        if rng.random() < 0.5:
            t.put_many([(k, 1000 + step * 100 + j) for j, k in enumerate(ks)])
            for j, k in enumerate(ks):
                model[k] = 1000 + step * 100 + j
        else:
            got = t.get_many(ks)
            for k, v in zip(ks, got):
                # RYW through pins: every key this client reads it also
                # wrote, so only the freshest value may be served
                assert v == model.get(k), (step, k, v, model.get(k))
    for be in cluster.blades.values():
        be.mirrors[0].set_lag(0)


def test_lagging_mirror_bytes_stay_out_of_cache_under_spike():
    """ReadTarget.cache_safe under a set_lag spike: bytes served by a
    lagging mirror are not inserted into the page cache, so post-spike
    primary reads see fresh values instead of cached stale ones."""
    be = NVMBackend(capacity=1 << 24, num_mirrors=1)
    fe = FrontEnd(be, FEConfig.rc())  # cache on
    ht = RemoteHashTable(fe, "h", n_buckets=64)
    for k in range(20):
        ht.put(k, k)
    fe.drain(ht.h)
    be.mirrors[0].set_lag(1 << 20)  # spike: replication frozen
    for k in range(20):
        ht.put(k, k + 500)
    fe.drain(ht.h)
    fe.cache.clear()  # drop write-through entries: force remote reads
    with fe.replica_reads(ReadPolicy(mode="mirror", max_staleness_ops=1 << 40)):
        stale = [ht.get(k) for k in range(20)]
    assert stale == list(range(20))          # bounded-stale, as contracted
    assert [ht.get(k) for k in range(20)] == [k + 500 for k in range(20)]
    be.mirrors[0].set_lag(0)  # spike ends: queued writes drain
    be.mirrors[0].sync()
    assert bytes(be.mirrors[0].arena) == bytes(be.arena)


# ------------------------------------------- write-lease fencing chaos
def test_steal_schedule_sweep_no_durability_or_fence_violations():
    """Two writers racing lease steals under lease_expiry + crash faults:
    every acked op survives, no stale-epoch op is ever committed, and the
    sweep genuinely exercises the steal path (steals > 0 per run)."""
    kinds = set()
    for seed in range(8):
        r = run_steal_schedule(seed)
        assert r.ok, f"seed {seed}: {r.violations[:5]}"
        assert r.stats["write_lease_steals"] > 0, f"seed {seed}: no steals"
        assert r.stats["stale_epoch_entries"] == 0
        kinds.update(r.injected)
    assert {"lease_expiry", "crash"} <= kinds, f"only {sorted(kinds)} injected"


def test_fenced_stale_writer_group_commit_vanishes_whole():
    """The tentpole fencing contract, deterministically: writer A stages a
    group-commit window, its lease expires, writer B acquires the shard
    (epoch bumps, no graceful surrender — A never saw the steal) and
    commits.  A's later flush must be rejected at the blade by the epoch
    fence: its staged ops vanish whole (never interleave with B's stream)
    and A's next read sees B's value."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    a = ClusterFrontEnd(cluster, FEConfig.rcb(), fe_id=0)
    b = ClusterFrontEnd(cluster, FEConfig.rcb(), fe_id=1)
    ta = ShardedHashTable(a, "f", n_buckets=256)
    tb = ShardedHashTable(b, "f", n_buckets=256)
    for k in range(16):
        ta.put(k, k)
    ta.drain()
    ta.put(3, 111)              # staged under A's epoch, not yet flushed
    # B's clock runs past the TTL: A's lease is expired at acquisition
    # time, so the epoch bumps with stolen=False and no surrender drain
    b.clock.advance_to(a.clock.now + cluster.lease_ttl_ns + 1)
    tb.put(3, 222)
    tb.drain()
    fenced0 = sum(fe.stats.fenced_appends for fe in a.fes.values())
    ta.drain()                  # flush rejected at the blade, then retried empty
    fenced1 = sum(fe.stats.fenced_appends for fe in a.fes.values())
    assert fenced1 > fenced0, "stale writer's group commit was not fenced"
    assert ta.get(3) == 222     # A's 111 vanished whole; A resynced
    assert tb.get(3) == 222
    assert _stale_epoch_total(cluster) == 0
    # untouched keys are unaffected by the fence
    assert ta.get_many([k for k in range(16) if k != 3]) == \
        [k for k in range(16) if k != 3]


# --------------------------------- replication channel v2: sim-time lag
def test_mirror_lag_ns_holds_bytes_until_sim_time():
    """set_lag_ns holds replicated units until now >= arrival + lag_ns,
    composes with lag_writes depth, and reads drain time-held units as
    sim time advances with no new writes."""
    be = NVMBackend(capacity=1 << 22, num_mirrors=1)
    m = be.mirrors[0]
    m.set_lag_ns(1_000.0)
    addr = be.heap_start
    t0 = be.clock.now
    be.write(addr, b"\xab" * 16)
    assert not m.synchronous
    assert bytes(m.arena[addr:addr + 16]) == b"\x00" * 16  # held by time
    assert m.read(addr, 16) == b"\x00" * 16                # still too young
    be.clock.advance_to(t0 + 1_001.0)
    assert m.read(addr, 16) == b"\xab" * 16  # read drained the held unit
    # depth AND delay compose: a unit applies only when both release it
    m.lag_writes = 4
    t1 = be.clock.now
    be.write(addr + 64, b"\xcd" * 8)
    be.clock.advance_to(t1 + 10_000.0)       # time constraint long released
    assert m.read(addr + 64, 8) == b"\x00" * 8  # depth still holds it
    for i in range(4):
        be.write(addr + 128 + i * 8, b"\xee" * 8)
    assert m.read(addr + 64, 8) == b"\xcd" * 8  # pushed through by depth
    # spike ends: zeroing both knobs + sync restores byte-identity
    m.lag_writes = 0
    m.set_lag_ns(0)
    m.sync()
    assert bytes(m.arena) == bytes(be.arena)
    assert m.synchronous


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 40),
       st.integers(min_value=0, max_value=999))
def test_lag_ns_spike_mid_run_never_violates_ryw_pins(spike_ns, seed):
    """Satellite regression: a *timestamp*-lagged mirror (set_lag_ns)
    injected mid-run composes with the staleness/RYW pins exactly like a
    depth-lagged one — every key this client wrote reads back fresh."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    policy = ReadPolicy(mode="auto", max_staleness_ops=8)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(cache_bytes=4096), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256, read_policy=policy)
    rng = random.Random(seed)
    model = {}
    pairs = [(k, k) for k in range(48)]
    t.put_many(pairs)
    model.update(pairs)
    for step in range(12):
        if step == 5:  # mid-run sim-time spike on every blade's mirror
            for be in cluster.blades.values():
                be.mirrors[0].set_lag_ns(float(spike_ns))
        if step == 8:  # compose: depth lag joins the time lag mid-wave
            for be in cluster.blades.values():
                be.mirrors[0].set_lag(3)
        ks = [rng.randrange(64) for _ in range(16)]
        if rng.random() < 0.5:
            t.put_many([(k, 1000 + step * 100 + j) for j, k in enumerate(ks)])
            for j, k in enumerate(ks):
                model[k] = 1000 + step * 100 + j
        else:
            got = t.get_many(ks)
            for k, v in zip(ks, got):
                assert v == model.get(k), (step, k, v, model.get(k))
    for be in cluster.blades.values():
        be.mirrors[0].set_lag_ns(0)
        be.mirrors[0].set_lag(0)


# ------------------------------------- crash -> reboot -> rejoin
def test_cold_reattach_replays_committed_tail_on_first_touch():
    """A writer dies with ops committed to the op log but not applied;
    the blades reboot; a COLD client — one that never bound these shards —
    must replay the tail on first touch instead of serving pre-crash
    state."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256)
    for k in range(40):
        t.put(k, k)
    t.drain()
    # second wave: per-op flush commits each entry, but the writer dies
    # before draining the applies
    for k in range(40):
        t.put(k, k + 1000)
    del t, cfe  # front-end crash: staged memory-log state is gone
    for be in cluster.blades.values():
        be.crash()
        be.reboot()
    cold = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=5)
    t2 = ShardedHashTable(cold, "t", n_buckets=256)
    assert t2.get_many(list(range(40))) == [k + 1000 for k in range(40)]


def test_cluster_reboot_rejoins_directory_with_epoch_bump():
    """handle_blade_failure distinguishes transient from permanent: a
    crashed blade reboots in place (no promotion), revokes leases, and
    bumps the epoch so every client rebinds."""
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    cfe = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=0)
    t = ShardedHashTable(cfe, "t", n_buckets=256)
    for k in range(30):
        t.put(k, k)
    t.drain()
    epoch0 = cluster.directory.epoch
    cluster.blades[1].crash()
    for k in range(30, 45):  # the data path notices and recovers
        t.put(k, k)
    assert cluster.failovers == 0          # transient: reboot, not promote
    assert cluster.directory.epoch > epoch0
    assert t.get_many(list(range(45))) == list(range(45))


# --------------------------------------------------- obs integration
def test_fault_metrics_and_counters_exported():
    try:
        with obs.observe(metrics=True) as sess:
            r = run_chaos_schedule(11, ensure=("nic_dead",))
            assert r.ok, r.violations[:3]
            totals, _ = sess.fe_totals()
            text = sess.build_registry().to_prometheus()
    finally:
        obs.stop()
    assert totals.get("op_retries", 0) >= 1
    assert totals.get("op_timeouts", 0) >= 1
    assert sess.counters.get("retries_total", 0) >= 1
    assert sess.counters.get("failovers_initiated", 0) >= 1
    assert sess.counters.get("fault_nic_dead", 0) >= 1
    assert "rnvm_fe_op_retries" in text
    assert "rnvm_retries_total" in text


def test_breaker_state_gauge_exported_per_blade():
    try:
        with obs.observe(metrics=True) as sess:
            cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                                 n_shards=4, num_mirrors=1)
            cfe = ClusterFrontEnd(cluster, FEConfig.rc(**DURABLE), fe_id=0)
            t = ShardedHashTable(cfe, "t", n_buckets=256)
            for k in range(20):
                t.put(k, k)
            t.drain()
            lk = cluster.blades[0].link
            lk.breaker = CircuitBreaker(cluster.cost)
            lk.breaker.opened_at = cfe.clock.now  # blade-0 breaker: open
            text = sess.build_registry().to_prometheus()
    finally:
        obs.stop()
    lines = [l for l in text.splitlines() if l.startswith("rnvm_breaker_state{")]
    assert len(lines) >= 2                          # one gauge per blade
    assert any('blade="0"' in l and l.endswith(" 1") for l in lines)
    assert any('blade="1"' in l and l.endswith(" 0") for l in lines)


def test_fault_plan_is_deterministic_and_sorted():
    p1 = FaultPlan.random(42, 100, 3)
    p2 = FaultPlan.random(42, 100, 3)
    assert p1.specs == p2.specs
    assert [s.at_op for s in p1.specs] == sorted(s.at_op for s in p1.specs)
    assert FaultPlan.random(43, 100, 3).specs != p1.specs


def test_injector_counts_and_finish_disarms():
    cluster = NVMCluster(n_blades=2, capacity_per_blade=1 << 22,
                         n_shards=4, num_mirrors=1)
    plan = FaultPlan.random(5, 50, 2, n_faults=5,
                            kinds=["wqe_drop", "nic_stall", "lag_spike"])
    inj = FaultInjector(plan, cluster, None)
    for i in range(50):
        inj.step(i)
    assert sum(inj.injected.values()) == 5
    inj.finish()
    for be in cluster.blades.values():
        f = be.link.fault
        assert f is None or (f.drop_pending == 0 and f.stall_until == 0.0)
        assert be._torn_write_at is None
