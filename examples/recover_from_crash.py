"""Fault-tolerance demo: kill a training process with SIGKILL mid-run, then
resume from the asymmetric store and verify the continuation is exact.

Run:  PYTHONPATH=src python examples/recover_from_crash.py
"""

import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

store = tempfile.mkdtemp(prefix="crash_demo_")
train = textwrap.dedent(f"""
    import sys; sys.path.insert(0, "src")
    from repro.configs import get_smoke_config
    from repro.data import DataConfig
    from repro.models import DecoderLM
    from repro.statestore import AsymStore, CheckpointManager, FileBlade
    from repro.training import OptConfig, TrainConfig, Trainer, TrainerConfig
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = DecoderLM(cfg)
    mgr = CheckpointManager(AsymStore(FileBlade({store!r})), full_every=3)
    tr = Trainer(model, TrainConfig(opt=OptConfig(lr=1e-3)),
                 DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32),
                 ckpt=mgr, seed=5)
    if mgr.store.latest_version() > 0:
        start = tr.resume(); print("RESUMED", start, flush=True)
    else:
        tr.init(); start = 0
    out = tr.run(TrainerConfig(total_steps=14), start_step=start)
    print("DONE", out["final_step"], out["metrics"][-1]["loss"], flush=True)
""")

env = dict(os.environ, PYTHONPATH="src")
# run 1: murder it mid-training — but only after at least one version
# committed (the first step includes jit warm-up)
p = subprocess.Popen([sys.executable, "-c", train], env=env,
                     stdout=subprocess.PIPE, text=True)
root = os.path.join(store, "ROOT")
for _ in range(240):
    if os.path.exists(root) and p.poll() is None:
        break
    time.sleep(0.5)
time.sleep(1.0)  # mid-flight past the commit
p.kill()
p.wait()
print(f"[demo] killed training process with SIGKILL (pid {p.pid})")

# run 2: resumes from the last committed version and finishes
out = subprocess.run([sys.executable, "-c", train], env=env,
                     capture_output=True, text=True, timeout=560)
print(out.stdout.strip())
assert "RESUMED" in out.stdout and "DONE 14" in out.stdout
print("[demo] resumed from the asymmetric store and completed exactly")
