"""Serving example: batched generation against a store version (SWMR reader)
or fresh weights; demonstrates version pinning + hot reload.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import DecoderLM
from repro.serving import ServeConfig, ServeEngine

cfg = get_smoke_config("llama3.2-3b")
model = DecoderLM(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = ServeEngine(model, params, ServeConfig(batch_slots=4, max_new_tokens=24))

rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)
tokens, stats = eng.generate(prompts)
print(f"generated {stats['decode_steps']} tokens/seq for {tokens.shape[0]} seqs")
print("first sequence:", tokens[0].tolist())
