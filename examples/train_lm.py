"""End-to-end training driver example: ~100M-param qwen-family model for a
few hundred steps on CPU with full fault-tolerance plumbing.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(defaults sized for a laptop; increase --steps/--d-model freely)
"""

import argparse
import dataclasses
import os
import tempfile

from repro.configs import get_config
from repro.data import DataConfig
from repro.models import DecoderLM, param_count
from repro.statestore import AsymStore, CheckpointManager, FileBlade
from repro.training import OptConfig, TrainConfig, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--store", default=None)
args = ap.parse_args()

# a ~100M-param member of the qwen1.5 family (exact arch, reduced width)
cfg = dataclasses.replace(
    get_config("qwen1.5-0.5b"),
    n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=8,
    head_dim=32, d_ff=args.d_model * 3, vocab_size=32000, max_cache_len=256,
)
model = DecoderLM(cfg)
print(f"model: {param_count(model.param_specs())/1e6:.1f}M params")

store_dir = args.store or tempfile.mkdtemp(prefix="asymstore_")
mgr = CheckpointManager(AsymStore(FileBlade(store_dir)), full_every=50,
                        delta_every=10, async_commit=True)
tr = Trainer(model, TrainConfig(opt=OptConfig(lr=3e-4), accum_steps=2),
             DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=128),
             ckpt=mgr, seed=0)
tr.install_preemption_handler()
if mgr.store.latest_version() > 0:
    start = tr.resume()
    print(f"resuming at step {start}")
else:
    tr.init()
    start = 0
out = tr.run(TrainerConfig(total_steps=args.steps), start_step=start)
mgr.close()
print(f"final loss: {out['metrics'][-1]['loss']:.4f} at step {out['final_step']}")
print(f"store: {store_dir} versions={AsymStore(FileBlade(store_dir)).committed_versions()}")
