"""Quickstart: the asymmetric persistent-state architecture in 60 lines.

1. rNVM core: a persistent B+Tree on a (simulated) remote NVM blade.
2. AsymStore: a tiny model trains, commits versions, crashes, resumes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax

# ----------------------------------------------------------------- 1. rNVM
from repro.core import FEConfig, FrontEnd, NVMBackend
from repro.core.structures import RemoteBPTree

blade = NVMBackend(capacity=1 << 24, num_mirrors=1)
fe = FrontEnd(blade, FEConfig.rcb(batch_ops=256))  # R+C+B optimizations on
tree = RemoteBPTree(fe, "accounts")
for k in range(1000):
    tree.insert(k, k * k)
fe.drain(tree.h)
print(f"[rNVM] 1000 inserts in {fe.clock.now/1e6:.2f} virtual ms "
      f"({1000/fe.clock.now*1e6:.0f} KOPS); find(77) = {tree.find(77)}")

# crash the blade mid-flight, reboot, recover from logs
blade.crash()
blade.reboot()
fe2 = FrontEnd(blade, FEConfig.rcb(), fe_id=1)
tree2 = RemoteBPTree.recover(fe2, "accounts")
assert tree2.find(77) == 77 * 77
print("[rNVM] blade rebooted; data intact via checksummed logs")

# ------------------------------------------------------------ 2. AsymStore
from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.models import DecoderLM
from repro.statestore import AsymStore, CheckpointManager, FileBlade
from repro.training import OptConfig, TrainConfig, Trainer, TrainerConfig

cfg = get_smoke_config("qwen1.5-0.5b")
model = DecoderLM(cfg)
with tempfile.TemporaryDirectory() as td:
    store = AsymStore(FileBlade(os.path.join(td, "blade")))
    mgr = CheckpointManager(store, full_every=4)
    tr = Trainer(model, TrainConfig(opt=OptConfig(lr=1e-3)),
                 DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32),
                 ckpt=mgr, seed=0)
    tr.init()
    out = tr.run(TrainerConfig(total_steps=6))
    print(f"[store] trained to step {out['final_step']}, "
          f"loss {out['metrics'][-1]['loss']:.3f}; versions: {store.committed_versions()}")

    tr2 = Trainer(model, TrainConfig(opt=OptConfig(lr=1e-3)),
                  DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=32),
                  ckpt=CheckpointManager(store, full_every=4), seed=0)
    start = tr2.resume()
    print(f"[store] replacement front-end resumed at step {start} (exact replay)")
