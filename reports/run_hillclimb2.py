import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time, traceback
sys.path.insert(0, "src")
from repro.launch.dryrun import analyze_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
EXPERIMENTS = [
    ("kimi_train_noremat", "kimi-k2-1t-a32b", "train_4k", {"remat": "none"}),
    ("llama_train_padheads_savedots", "llama3.2-3b", "train_4k",
     {"pad_heads": 8, "remat": "save_dots"}),
]
out = json.load(open("reports/hillclimb.json"))
for tag, arch, shape, ov in EXPERIMENTS:
    try:
        rec = analyze_cell(arch, shape, mesh, overrides=ov)
        rec["tag"] = tag; rec["status"] = "ok"
        r = rec["roofline"]
        print(f"[hc] {tag}: tc={r['compute_s']:.3f} tm={r['memory_s']:.3f} "
              f"tn={r['collective_s']:.3f} bound={r['bottleneck']}", flush=True)
    except Exception as e:
        rec = {"tag": tag, "status": "fail", "error": str(e)}
        print(f"[hc] {tag}: FAIL {e}", flush=True)
    out.append(rec)
    json.dump(out, open("reports/hillclimb.json", "w"), indent=1, default=float)
print("done")
