import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time, traceback
sys.path.insert(0, "src")
from repro.launch.dryrun import analyze_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
EXPERIMENTS = [
    # (tag, arch, shape, overrides)
    ("kimi_decode_baseline", "kimi-k2-1t-a32b", "decode_32k", None),
    ("mamba_train_bf16scan", "falcon-mamba-7b", "train_4k", {"scan_bf16": True}),
    ("llava_train_padheads", "llava-next-34b", "train_4k", {"pad_heads": 8}),
    ("kimi_train_savedots", "kimi-k2-1t-a32b", "train_4k", {"remat": "save_dots"}),
    ("llama_train_padheads", "llama3.2-3b", "train_4k", {"pad_heads": 8}),
    ("mamba_prefill_bf16scan", "falcon-mamba-7b", "prefill_32k", {"scan_bf16": True}),
    ("llava_train_padheads_savedots", "llava-next-34b", "train_4k",
     {"pad_heads": 8, "remat": "save_dots"}),
]
out = []
for tag, arch, shape, ov in EXPERIMENTS:
    t0 = time.time()
    try:
        rec = analyze_cell(arch, shape, mesh, overrides=ov)
        rec["tag"] = tag
        rec["status"] = "ok"
        r = rec["roofline"]
        print(f"[hc] {tag}: tc={r['compute_s']:.3f} tm={r['memory_s']:.3f} "
              f"tn={r['collective_s']:.3f} bound={r['bottleneck']} "
              f"useful={rec['useful_flops_fraction']:.2f} ({time.time()-t0:.0f}s)", flush=True)
    except Exception as e:
        rec = {"tag": tag, "status": "fail", "error": str(e),
               "traceback": traceback.format_exc()[-1500:]}
        print(f"[hc] {tag}: FAIL {e}", flush=True)
    out.append(rec)
    json.dump(out, open("reports/hillclimb.json", "w"), indent=1, default=float)
print("hillclimb done")
